"""Span-partitioned serving: pipelined partial-stack engines (§4.1).

A *span pipeline* hosts one logical serving instance across several
partial-stack stages: stage *k* owns a contiguous layer span (weights +
that span's paged KV pool) and the batch's residual stream flows stage to
stage each forward, so outputs are token-identical to a monolithic engine
(asserted by tests/test_layer_span.py).  This is the execution substrate
of the paper's layer-level migration (Eq. 5, Fig. 3): moving the boundary
between two adjacent stages re-slices their weight shards and moves only
the boundary layers' per-slot KV pages — cost scales with the moved span,
never the stack.

* ``PrefillPipeline`` — chained prefill.  The lead stage runs the normal
  bucketed wave loop (serving/engine.py) and hands each wave's residual
  stream down the chain; per-span states merge back into the universal
  full-stack wire format, so a span-partitioned prefill hands off to ANY
  decode instance (span or monolithic) unchanged.
* ``DecodePipeline`` — chained continuous-batching decode.  All stages
  keep identical slot layouts (the lead owns request lifecycles, the
  followers mirror its commits), inserts split the wire state per span,
  extracts merge it back, and ``move_span`` executes a live
  ``MigrationKind.LAYER`` action between adjacent stages.

States cross stage boundaries in *canonical* form: a leaf is paged iff
its cache length equals the full stack's page length (the wire contract
of models/kvcache.py); stages whose own page space is smaller (ring-only
spans) page internally and de-page on exit.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import layer_migration as LM
from ..models import kvcache as KC
from ..models.config import ModelConfig
from .engine import (DecodeEngine, EngineConfig, PrefillEngine,
                     _paged_page_len)
from .request import Phase, Request


def _check_bounds(bounds: Sequence[Tuple[int, int]], n_layers: int) -> None:
    assert bounds and bounds[0][0] == 0 and bounds[-1][1] == n_layers, \
        f"bounds {bounds} must partition [0, {n_layers})"
    for (_, b0), (a1, _) in zip(bounds, bounds[1:]):
        assert b0 == a1, f"bounds not contiguous: {bounds}"
    assert all(b > a for a, b in bounds), f"empty span in {bounds}"


class PrefillPipeline:
    """A prefill instance partitioned into chained layer-span stages.

    Presents the ``PrefillEngine`` surface the orchestrator and tests use
    (enqueue / run / run_batch / run_queued / load_report); the lead stage
    does the bucketing and drives the chain wave by wave."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 bounds: Sequence[Tuple[int, int]], name: str = "pp0"):
        _check_bounds(bounds, cfg.n_layers)
        self.cfg = cfg
        self.ecfg = ecfg
        self.name = name
        self.engines = [
            PrefillEngine(cfg, params, ecfg, None,
                          name=f"{name}.{k}", layer_span=span)
            for k, span in enumerate(bounds)]
        self.engines[0]._followers = self.engines[1:]

    @property
    def bounds(self) -> List[Tuple[int, int]]:
        return [e.layer_span for e in self.engines]

    @property
    def lead(self) -> PrefillEngine:
        return self.engines[0]

    @property
    def queue(self):
        return self.lead.queue

    def enqueue(self, req: Request) -> None:
        self.lead.enqueue(req)
        req.prefill_instance = self.name

    def load_report(self):
        return self.lead.load_report()

    def run_batch(self, reqs, frames=None, chunk_tokens=None):
        return self.lead.run_batch(reqs, frames=frames,
                                   chunk_tokens=chunk_tokens)

    def run(self, req: Request, frames=None):
        return self.lead.run(req, frames=frames)

    def run_queued(self, max_reqs: int, frames=None, chunk_tokens=None):
        return self.lead.run_queued(max_reqs, frames=frames,
                                    chunk_tokens=chunk_tokens)

    def prefill_waves(self, reqs, frames=None, chunk_tokens=None):
        """Wave generator over the chained stages (see PrefillEngine):
        each wave's residual stream flows through every span in turn."""
        return self.lead.prefill_waves(reqs, frames=frames,
                                       chunk_tokens=chunk_tokens)

    def move_span(self, src: int, dst: int, n: int) -> Optional[int]:
        """Shift ``n`` boundary layers from stage ``src`` to adjacent
        stage ``dst``.  Prefill stages hold no resident serving state, so
        only the weight shards re-slice; returns moved layer count."""
        assert abs(src - dst) == 1, "span moves are between adjacent stages"
        ei, ej = self.engines[src], self.engines[dst]
        (a, b) = ei.layer_span
        n = min(n, (b - a) - 1)
        if n <= 0:
            return None
        if dst == src + 1:           # tail of src -> head of dst
            ei.rebase_span((a, b - n))
            ej.rebase_span((b - n, ej.layer_span[1]))
        else:                        # head of src -> tail of dst
            ei.rebase_span((a + n, b))
            ej.rebase_span((ej.layer_span[0], a + n))
        return n


class DecodePipeline:
    """A decode instance partitioned into chained layer-span stages.

    All stages share one slot layout: the lead stage owns request
    lifecycles and token streams; followers mirror its commits.  The
    pipeline speaks the universal wire format at its edges (insert /
    extract / drain), so span pipelines, monolithic engines and pipelines
    with *different* boundaries interoperate freely."""

    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig,
                 bounds: Sequence[Tuple[int, int]], name: str = "dp0",
                 engines: Optional[Sequence[DecodeEngine]] = None):
        _check_bounds(bounds, cfg.n_layers)
        self.cfg = cfg
        self.ecfg = ecfg
        self.name = name
        if engines is None:
            engines = [DecodeEngine(cfg, params, ecfg, name=f"{name}.{k}",
                                    layer_span=span)
                       for k, span in enumerate(bounds)]
        self.engines: List[DecodeEngine] = list(engines)
        assert [tuple(e.layer_span) for e in self.engines] == \
            [tuple(b) for b in bounds]
        # the wire contract: leaves are paged iff their cache length equals
        # the FULL stack's page space (None -> wire states are dense)
        self._wire_plen = _paged_page_len(cfg, ecfg)
        self.span_moves: List[Tuple[int, int, int]] = []  # (src, dst, n)

    # -- lead-delegated views --------------------------------------------
    @property
    def bounds(self) -> List[Tuple[int, int]]:
        return [e.layer_span for e in self.engines]

    @property
    def lead(self) -> DecodeEngine:
        return self.engines[0]

    @property
    def slots(self) -> List[Optional[Request]]:
        return self.lead.slots

    @property
    def active(self) -> int:
        return self.lead.active

    @property
    def free_slots(self) -> int:
        return self.lead.free_slots

    @property
    def kv_tokens(self) -> int:
        return self.lead.kv_tokens

    @property
    def tokens_decoded(self) -> int:
        return self.lead.tokens_decoded

    def free_slot(self) -> Optional[int]:
        return self.lead.free_slot()

    # -- wire-format edges -----------------------------------------------
    def _canon_state(self, e: DecodeEngine, st: Dict[str, Any]
                     ) -> Dict[str, Any]:
        """De-page a stage's state when its own page space differs from
        the wire's (ring-only spans page internally at the window)."""
        if "n_blocks" in st and e.page_len != self._wire_plen:
            st = KC.paged_state_to_dense(st, self.ecfg.block_size,
                                         e.page_len)
        return st

    def adopt(self, req: Request, state: Dict[str, Any],
              next_token: int, slot: Optional[int] = None,
              shared_pages: Optional[Sequence[Tuple[int, ...]]] = None
              ) -> int:
        """Migration receive path: split the wire state at this pipeline's
        boundaries and land each part on its stage, same slot everywhere.

        ``shared_pages`` is the pipeline form of the zero-copy bind: one
        per-stage tuple of physical pages per shared block (the layout
        ``slot_pages`` reports), bound by reference on every stage —
        stages COW independently at their own divergence points, so a
        fork on one stage never perturbs the others.  ``state`` must
        already be head-split past the shared blocks.  The orchestrator's
        store never registers pipeline pools (their pages die on
        ``move_span``/``rebase_span``); this path serves direct sharing
        between pipeline slots, where a live span move simply gathers the
        shared content and re-adopts it unshared — correctness is kept,
        sharing is dropped."""
        if slot is None:
            slot = self.lead.free_slot()
        assert slot is not None, "decode pipeline full"
        shared = list(shared_pages or ())
        parts = LM.split_state_spans(self.cfg, state, self.bounds)
        for k, (e, part) in enumerate(zip(self.engines, parts)):
            sp = [t[k] for t in shared] if shared else None
            if sp:
                assert e.paged and e.page_len == self._wire_plen, \
                    "shared-page binds need every stage paged at the wire"
            e.adopt(req, part, next_token, slot=slot, shared_pages=sp)
        req.decode_instance = self.name
        return slot

    def insert(self, req: Request, state: Dict[str, Any],
               first_token: int,
               shared_pages: Optional[Sequence[Tuple[int, ...]]] = None
               ) -> int:
        """KV transfer: place a prefilled request into a decode slot."""
        slot = self.adopt(req, state, int(first_token),
                          shared_pages=shared_pages)
        req.generated.append(int(first_token))
        req.advance(Phase.DECODE)
        return slot

    def slot_pages(self, slot: int) -> List[Tuple[int, ...]]:
        """Per-block page tuples backing ``slot`` — element ``j`` holds
        block ``j``'s physical page on every stage, the layout ``adopt``'s
        ``shared_pages`` consumes."""
        return list(zip(*(e.slot_pages(slot) for e in self.engines)))

    def extract_slot(self, slot: int
                     ) -> Tuple[Request, Dict[str, Any], int]:
        """Pull a slot off every stage and merge back into the wire format
        (migration send path)."""
        parts, req, tok = [], None, 0
        for e in self.engines:
            req, st, tok = e.extract_slot(slot)
            parts.append(self._canon_state(e, st))
        merged = LM.merge_state_spans(self.cfg, parts, self.bounds)
        return req, merged, tok

    def drain(self) -> List[Tuple[Request, Dict[str, Any], int]]:
        return [self.extract_slot(i) for i, s in enumerate(self.lead.slots)
                if s is not None]

    def release_slot(self, slot: int) -> Request:
        """Abort path: free the slot (and its paged blocks) on every
        stage without gathering any state."""
        req = self.lead.slots[slot]
        for e in self.engines:
            e.release_slot(slot)
        return req

    # -- pipelined decode -------------------------------------------------
    def step(self) -> List[Tuple[Request, int]]:
        """One decode iteration: the token column enters stage 0, the
        residual stream chains through every span, logits exit the last
        stage; the lead commits and followers mirror."""
        if self.active == 0:
            return []
        for e in self.engines:
            e._prepare_pages()
        x = jnp.asarray(self.lead.next_token[:, None])
        last = len(self.engines) - 1
        for k, e in enumerate(self.engines):
            x = e._forward_step(x, hidden_in=k > 0, hidden_out=k < last)
        nxt = np.asarray(jnp.argmax(x, axis=-1), np.int32)
        finished = self.lead.commit(nxt)
        done_slots = {s for _, s in finished}
        for e in self.engines[1:]:
            e.follow_commit(nxt, done_slots)
        return finished

    # -- layer-span migration ---------------------------------------------
    def move_span(self, src: int, dst: int, n: int
                  ) -> Optional[Dict[str, int]]:
        """Live §4.1 span move: shift ``n`` boundary layers (weights + the
        active slots' per-layer KV) from stage ``src`` to adjacent stage
        ``dst`` without perturbing any token stream.

        Returns ``{"layers": moved, "weight_bytes": …, "kv_bytes": …,
        "schedule": [(abs_layer, nbytes), …]}`` — the ordered per-layer
        payload ``analytical.overlapped_schedule_time`` bills (Eq. 4/11)
        — or None if the move is infeasible (stages not adjacent in span
        order, or it would empty ``src``)."""
        assert abs(src - dst) == 1, "span moves are between adjacent stages"
        ei, ej = self.engines[src], self.engines[dst]
        a, b = ei.layer_span
        n = min(n, (b - a) - 1)
        if n <= 0:
            return None
        moved = (b - n, b) if dst == src + 1 else (a, a + n)
        union = (min(a, ej.layer_span[0]), max(b, ej.layer_span[1]))
        old_pair = [ei.layer_span, ej.layer_span] if dst == src + 1 \
            else [ej.layer_span, ei.layer_span]
        if dst == src + 1:
            new_pair = [(a, b - n), (b - n, ej.layer_span[1])]
        else:
            new_pair = [(ej.layer_span[0], a + n), (a + n, b)]

        # snapshot every active slot's state across BOTH stages (other
        # stages keep serving theirs untouched), merged over the union span
        lo, hi = (ei, ej) if dst == src + 1 else (ej, ei)
        snap: List[Tuple[int, Request, int, Dict[str, Any]]] = []
        for s in range(self.ecfg.max_batch):
            if ei.slots[s] is None:
                continue
            parts = []
            req, tok = None, 0
            for e in (lo, hi):
                req, st, tok = e.extract_slot(s)
                parts.append(self._canon_state(e, st))
            snap.append((s, req, tok,
                         LM.merge_state_spans(self.cfg, parts, old_pair)))

        # account the migrated payload: the moved layers' weight shard +
        # their share of every resident slot's serving state, as the
        # ordered per-layer schedule Eq. 4/11 bills (absolute indices)
        payload_layers = LM.unstack_layers(self.cfg, self.lead.params)
        per_layer = {l: LM.layer_param_bytes(payload_layers[l][1])
                     for l in range(moved[0], moved[1])}
        w_bytes = sum(per_layer.values())
        kv_bytes = 0
        for _, _, _, merged in snap:
            mv = LM.split_state_spans(self.cfg, merged, [moved],
                                      base=union)[0]
            for l, nbytes in KC.layer_transfer_schedule(
                    mv, base_layer=moved[0]):
                per_layer[l] += nbytes
                kv_bytes += nbytes
        schedule = sorted(per_layer.items())

        lo.rebase_span(new_pair[0])
        hi.rebase_span(new_pair[1])
        for s, req, tok, merged in snap:
            new_parts = LM.split_state_spans(self.cfg, merged, new_pair,
                                             base=union)
            lo.adopt(req, new_parts[0], tok, slot=s)
            hi.adopt(req, new_parts[1], tok, slot=s)
        self.span_moves.append((src, dst, n))
        return {"layers": n, "weight_bytes": int(w_bytes),
                "kv_bytes": int(kv_bytes), "schedule": schedule}
