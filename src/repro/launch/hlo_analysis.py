"""Post-compile HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective traffic, so
we parse the compiled module text: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute instruction's output shape
is a lower bound on bytes moved per execution.

Collectives inside ``while`` bodies (layer scans, q-block scans) execute
once per trip; we reconstruct the computation call graph from the HLO text
and multiply by the static trip counts the caller supplies per nesting
depth (depth 1 = the layer scan, depth 2 = the q-block scan).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*\)|[^\s(]+))\s+"
    r"([\w\-]+)\(([^\n]*)$", re.M)
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*\)|[^\s(]+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_COMP_RE = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$", re.M)
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count[^\d]*(\d+)")
_CALL_RE = re.compile(r"(?:to_apply=|condition=|calls=|"
                      r"branch_computations=\{)%?([\w.\-]+)")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string (sums tuple elements)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]
    # loop-weighted whole-program costs (XLA's cost_analysis() counts while
    # bodies ONCE — verified on this backend — so we re-derive them from the
    # HLO text with the call-graph trip multipliers):
    dot_flops: float = 0.0
    hlo_bytes: float = 0.0          # 2x output bytes of non-trivial ops

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def _dot_flops(line: str, shape_str: str, operands: str,
               shapes: Dict[str, str]) -> float:
    """FLOPs of a dot instruction: 2 * prod(output dims) * contraction."""
    out = 0
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",") if d]
    prod_out = 1
    for d in dims:
        prod_out *= d
    # contraction size from the lhs operand's contracting dims
    cm = _DOT_DIMS_RE.search(line)
    # operand names: prefer %-prefixed tokens — newer jaxlib prints each
    # operand with its full shape ("f32[256,256]{1,0} %lhs"), so a bare
    # token scan would pick up the dtype instead of the name
    seg = operands.split(")")[0]
    ops = re.findall(r"%([\w.\-]+)", seg) or re.findall(r"([\w.\-]+)", seg)
    if not cm or not ops:
        return 2.0 * prod_out
    lhs_shape = shapes.get(ops[0], "")
    lm = _SHAPE_RE.search(lhs_shape)
    if not lm:
        return 2.0 * prod_out
    lhs_dims = [int(d) for d in lm.group(2).split(",") if d]
    contr = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_dims):
            contr *= lhs_dims[int(idx)]
    return 2.0 * prod_out * contr


_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "copy", "while", "call", "conditional", "custom-call",
             "after-all", "iota", "broadcast", "reshape"}


def parse_collectives(hlo_text: str,
                      loop_trip_counts: Tuple[int, ...] = (1,),
                      ) -> CollectiveStats:
    """Sum collective output bytes — plus loop-weighted dot FLOPs and
    approximate HBM traffic — weighting by loop nesting.

    loop_trip_counts[d] = trips of a depth-(d+1) while loop; deeper nesting
    reuses the last entry.
    """
    # split the module into computations
    comps: Dict[str, str] = {}
    current = None
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _COMP_RE.match(ln)
        if m:
            # computation header: %name (args...) -> shape {   (args may
            # contain nested parens for tuple-typed parameters)
            current = m.group(1)
            comps[current] = ""
            continue
        if current is not None:
            comps[current] = comps[current] + ln + "\n"

    # call graph: computation -> [(child, trip_multiplier)].  While bodies
    # carry their exact static trip count in backend_config
    # ("known_trip_count"); fall back to the caller-supplied depth table.
    children: Dict[str, List[Tuple[str, int]]] = {}
    for name, body in comps.items():
        kids: List[Tuple[str, int]] = []
        for ln in body.splitlines():
            bm = _BODY_RE.search(ln)
            if bm and bm.group(1) in comps:
                tm = _TRIP_RE.search(ln)
                kids.append((bm.group(1), int(tm.group(1)) if tm else 0))
            for ref in _CALL_RE.findall(ln):
                if ref in comps:
                    kids.append((ref, 1))
        children[name] = kids

    # entry = computation that nobody calls
    called = {c for kids in children.values() for c, _ in kids}
    entries = [c for c in comps if c not in called]

    def trip(depth: int) -> int:
        if depth <= 0:
            return 1
        idx = min(depth - 1, len(loop_trip_counts) - 1)
        return max(int(loop_trip_counts[idx]), 1)

    bytes_by_kind: Dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    count_by_kind: Dict[str, int] = {k: 0 for k in COLLECTIVES}
    totals = {"flops": 0.0, "bytes": 0.0}

    # name -> shape string, per computation (names are module-unique in
    # post-optimization HLO)
    shapes: Dict[str, str] = {}
    for m in _DEF_RE.finditer(hlo_text):
        shapes[m.group(1)] = m.group(2)

    seen: Dict[Tuple[str, int], bool] = {}

    def walk(comp: str, depth: int, mult: float):
        if (comp, depth) in seen:
            return
        seen[(comp, depth)] = True
        body = comps.get(comp, "")
        for m in _INSTR_RE.finditer(body):
            shape_str, kind = m.group(1), m.group(2)
            b = _shape_bytes(shape_str)
            bytes_by_kind[kind] += b * mult
            count_by_kind[kind] += 1
        for m in _DEF_RE.finditer(body):
            name, shape_str, op, rest = m.groups()
            if op in _SKIP_OPS:
                continue
            out_b = _shape_bytes(shape_str)
            totals["bytes"] += 2.0 * out_b * mult     # ~read + write
            if op == "dot":
                totals["flops"] += _dot_flops(m.group(0), shape_str, rest,
                                              shapes) * mult
        for kid, trips_known in children.get(comp, []):
            if kid == comp:
                continue
            if trips_known == 1:
                walk(kid, depth, mult)
            elif trips_known > 1:
                walk(kid, depth + 1, mult * trips_known)
            else:   # while body with unknown trips: use the depth table
                walk(kid, depth + 1, mult * trip(depth + 1))

    for e in entries:
        walk(e, 0, 1.0)
    return CollectiveStats(bytes_by_kind, count_by_kind,
                           dot_flops=totals["flops"],
                           hlo_bytes=totals["bytes"])


# ---------------------------------------------------------------------------
# Roofline terms (per the assignment's hardware constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12        # bf16 / chip (TPU v5e)
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link


@dataclasses.dataclass
class Roofline:
    """Per-(arch, shape, mesh) roofline terms.

    IMPORTANT semantics: ``hlo_flops`` / ``hlo_bytes`` / ``collective_bytes``
    come from the compiled SPMD module text, which is the PER-DEVICE
    program — they are already per-chip quantities.  ``model_flops`` is the
    GLOBAL 6·N·D / 2·N·D number.
    """
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float               # per chip
    hlo_bytes: float               # per chip (analytical — see dryrun)
    collective_bytes: float        # per chip
    model_flops: float             # global
    bytes_per_chip: float          # peak HBM residency per chip

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flop_ratio(self) -> float:
        total_hlo = self.hlo_flops * self.n_chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    def as_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "bytes_per_chip": self.bytes_per_chip,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
        }
