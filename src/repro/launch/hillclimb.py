import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: lower+compile a (arch x shape) pair under a named
variant and record the same roofline metrics as the dry-run baseline, into
experiments/perf/<arch>__<shape>__<variant>.json.

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch llama3-405b --shape decode_32k --variant kv_int8
"""
import argparse
import json
import time
import traceback

import jax

from .. import configs
from ..models.config import ModelConfig
from . import hlo_analysis as H
from . import specs as S
from . import steps
from .dryrun import _loop_trips, analytical_bytes_per_chip, model_flops
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "perf")

VARIANTS = {
    "baseline": {},
    "kv_int8": {"kv_quant": True},
    "logits_sharded": {"shard_logits": True},
    "kv_int8+logits_sharded": {"kv_quant": True, "shard_logits": True},
    "w_int8": {"weight_quant": True},
    "w_int8+kv_int8": {"weight_quant": True, "kv_quant": True},
    "w_int8+kv_int8+logits_sharded": {"weight_quant": True,
                                      "kv_quant": True,
                                      "shard_logits": True},
    "moe_dense": {"moe_impl": "dense"},
    "moe_local_sorted": {"moe_impl": "local_sorted"},
    "moe_local+w_int8": {"moe_impl": "local_sorted", "weight_quant": True},
    "pipeline": {"pipeline": True},
    "pipeline+kv_int8": {"pipeline": True, "kv_quant": True},
    "pipeline+kv_int8+w_int8": {"pipeline": True, "kv_quant": True,
                                "weight_quant": True},
    "moe_sorted_cf1": {"moe_cf": 1.0},
    "moe_sorted_cf2": {"moe_cf": 2.0},
    "moe_nodrop": {"moe_cf": None},
}


def _build_pipeline(cfg0, shape, mesh, knobs):
    """Pipeline-parallel decode lowering (§Perf pair-1 iter 4)."""
    import dataclasses

    import jax.numpy as jnp
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    from ..models.quant import quantize_weights
    from .pipeline_decode import (build_pipeline_decode, pad_stacked_cache,
                                  pad_stacked_params)
    from .sharding import ShardingPolicy, tree_shardings
    assert shape.kind == "decode"
    cfg = S.arch_for_shape(cfg0, shape)
    if knobs.get("kv_quant"):
        cfg = cfg.with_kv_quant()
    fn, per_stage, n_pad = build_pipeline_decode(cfg, mesh,
                                                 shape.global_batch)
    params = S.param_shapes(cfg, jnp.bfloat16)
    params = jax.eval_shape(lambda p: pad_stacked_params(cfg, p, n_pad),
                            params)
    if knobs.get("weight_quant"):
        params = jax.eval_shape(quantize_weights, params)
    cache = S.cache_shapes(cfg, shape.global_batch, shape.seq_len,
                           jnp.bfloat16)
    cache = jax.eval_shape(lambda c: pad_stacked_cache(c, n_pad), cache)
    # stage ("data") sharding on the layer-stack dim, TP ("model") within
    pol = ShardingPolicy(mesh, dataclasses.replace(cfg, fsdp_weights=False))
    p_sh = tree_shardings(pol, params, "param")

    def restage(path, ns):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if names[0] != "groups":
            return ns
        spec = list(ns.spec) + [None] * (len(ns.spec) == 0)
        spec = list(ns.spec)
        if not spec:
            spec = [None]
        spec[0] = "data"
        return NamedSharding(mesh, P(*spec))
    p_sh = jax.tree_util.tree_map_with_path(restage, p_sh)
    c_sh = tree_shardings(pol, cache, "cache")

    def restage_cache(path, ns):
        names = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if names[0] != "groups":
            return ns
        spec = list(ns.spec)
        if not spec:
            spec = [None]
        spec[0] = "data"
        if len(spec) > 1:
            spec[1] = None          # full batch per stage
        return NamedSharding(mesh, P(*spec))
    c_sh = jax.tree_util.tree_map_with_path(restage_cache, c_sh)
    tok_sh = NamedSharding(mesh, P())
    rep = NamedSharding(mesh, P())
    args = (params, jax.ShapeDtypeStruct((shape.global_batch, 1),
                                         jnp.int32), cache)
    return fn, args, (p_sh, tok_sh, c_sh), (rep, c_sh), (2,), cfg


def run_variant(arch: str, shape_name: str, variant: str,
                mesh_kind: str = "single", out_dir: str = OUT_DIR) -> dict:
    cfg0 = configs.get(arch)
    shape = S.SHAPES[shape_name]
    knobs = VARIANTS[variant]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(mesh.devices.size)
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "mesh": mesh_kind, "ok": False}
    t0 = time.time()
    try:
        if knobs.get("pipeline"):
            fn, args, in_sh, out_sh, donate, cfg = _build_pipeline(
                cfg0, shape, mesh, knobs)
        else:
            fn, args, in_sh, out_sh, donate = steps.build(cfg0, shape, mesh,
                                                          **knobs)
            cfg = S.arch_for_shape(cfg0, shape)
            if knobs.get("kv_quant"):
                cfg = cfg.with_kv_quant()
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                               donate_argnums=donate).lower(*args).compile()
        mem = compiled.memory_analysis()
        coll = H.parse_collectives(compiled.as_text(),
                                   _loop_trips(cfg, shape))
        byts = analytical_bytes_per_chip(cfg, shape, n_chips, mesh)
        if knobs.get("weight_quant"):
            # int8 weights: resident + read traffic of weights halve
            model_axis = mesh.shape["model"]
            w_chip = cfg.active_param_count() * 2 / (
                n_chips if cfg.fsdp_weights else model_axis)
            byts -= 0.5 * w_chip
        if knobs.get("kv_quant") and shape.kind != "train":
            # int8 cache: KV reads halve (scales are ~1% of payload)
            kv_len = cfg.kv_cache_len(shape.seq_len)
            kv_total = cfg.kv_bytes_per_token() * kv_len * shape.global_batch
            byts -= 0.5 * kv_total / n_chips
        resident = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes)
        roof = H.Roofline(arch, shape_name, mesh_kind, n_chips,
                          coll.dot_flops, byts, coll.total_bytes,
                          model_flops(cfg, shape), resident)
        rec.update({
            "ok": True, "compile_s": time.time() - t0,
            "resident_bytes_per_chip": resident,
            "temp_arena_bytes": mem.temp_size_in_bytes,
            "collective_detail": coll.bytes_by_kind,
            "roofline": roof.as_dict(),
        })
        ro = rec["roofline"]
        print(f"{arch} {shape_name} [{variant:24}] "
              f"comp={ro['t_compute_s']*1e3:7.3f}ms "
              f"mem={ro['t_memory_s']*1e3:7.3f}ms "
              f"coll={ro['t_collective_s']*1e3:7.3f}ms "
              f"resident={resident/2**30:6.2f}GiB "
              f"bottleneck={ro['bottleneck']}")
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
        print(f"{arch} {shape_name} [{variant}] FAIL {rec['error'][:100]}")
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(
            out_dir, f"{arch}__{shape_name}__{variant}.json"), "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline",
                    choices=sorted(VARIANTS))
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant, args.mesh)
    return 0 if rec["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
