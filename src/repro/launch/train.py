"""Training driver: real steps on the host devices (CPU here, TPU mesh in
production via the same sharding policy).

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b --smoke \\
        --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from .. import configs
from ..data.pipeline import DataConfig, SyntheticTokens
from ..models import transformer as T
from ..training import checkpoint as C
from ..training import optimizer as O
from ..training.train_step import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-13b")
    ap.add_argument("--smoke", action="store_true",
                    help="train the reduced variant (CPU-sized)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"arch={cfg.name} params={cfg.param_count():,}")

    key = jax.random.PRNGKey(0)
    params = T.init(cfg, key)
    opt_cfg = O.AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                            total_steps=args.steps)
    opt_state = O.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      num_microbatches=args.microbatches))
    data = iter(SyntheticTokens(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch)))

    t0 = time.time()
    for step in range(1, args.steps + 1):
        raw = next(data)
        batch = {"tokens": jnp.asarray(raw["tokens"])}
        if cfg.cross_attention:
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.n_frames, cfg.d_model), jnp.float32)
        params, opt_state, m = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == 1:
            print(f"step {step:5d}  loss {float(m['loss']):.4f}  "
                  f"nll {float(m['nll']):.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"({(time.time() - t0) / step * 1e3:.0f} ms/step)")
    if args.ckpt:
        C.save(args.ckpt, params, step=args.steps,
               meta={"arch": cfg.name})
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
