"""Serving CLI: the session-oriented front door over either backend.

    # analytical cluster simulation (no model compute, paper-scale configs)
    PYTHONPATH=src python -m repro.launch.serve --backend sim --smoke

    # live disaggregated fleet over the real JAX model (virtual clock)
    PYTHONPATH=src python -m repro.launch.serve --backend live --smoke \\
        --arch gemma-7b --requests 12

The pre-orchestrator wall-clock loop that used to live here (one
prefill/decode pair, no routing, no migration) is retired: both backends
are now driven through ``serving.api.Server`` — submit / stream / abort /
drain — so this CLI exercises exactly the surface production drivers,
benchmarks and the contract tests use.  ``--closed-loop K`` switches the
workload from open-loop Poisson arrivals to ``K`` fixed-concurrency
clients (each completion triggers the next submission);
``--admission-limit M`` bounds in-flight requests, with overflow REJECTED
and reported in the summary.
"""
from __future__ import annotations

import argparse

from .. import configs
from ..serving.api import Server
from ..serving.workload import ClosedLoopClients, WorkloadConfig, generate


def _build_live(args):
    import jax

    from ..core import analytical as A
    from ..models import transformer as T
    from ..serving.engine import EngineConfig
    from ..serving.orchestrator import Orchestrator, OrchestratorConfig

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"live backend: arch={cfg.name} params={cfg.param_count():,}")
    params = T.init(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_len=args.max_len, max_batch=args.max_batch,
                        block_size=16, speculation=args.speculation)
    draft = None
    if args.speculation == "draft":
        # reuse the arch's smoke shrink as the small draft stack — same
        # tokenizer space, fraction of the layers/width
        dcfg = configs.get(args.arch).smoke()
        if dcfg == cfg:     # already smoke-sized: self-draft
            dcfg = cfg
        draft = (dcfg, params if dcfg == cfg
                 else T.init(dcfg, jax.random.PRNGKey(1)))
        print(f"draft model: {dcfg.name} params={dcfg.param_count():,}")
    hw = A.TPU_V5E
    # --rps is in arrivals per decode-iteration time, so the offered load
    # is meaningful at any model scale on the virtual clock
    t_iter = A.decode_iter_time(cfg, args.max_len, hw, batch=args.max_batch)
    wl = WorkloadConfig(kind="synthetic", rps=args.rps / t_iter,
                        n_requests=args.requests, vocab_size=cfg.vocab_size,
                        max_new_tokens=args.max_new,
                        prefix_share=args.prefix_share, n_prefix_groups=2,
                        prompt_len_lo=16,
                        prompt_len_hi=min(64, args.max_len // 2))
    orch = Orchestrator(cfg, params, OrchestratorConfig(
        n_prefill=args.prefill, n_decode=args.decode, engine=ecfg, hw=hw,
        chunk_tokens=32), draft=draft)
    return orch, wl, 1e6  # report in virtual microseconds


def _build_sim(args):
    import dataclasses

    from ..serving.cluster import ClusterSim, SimConfig

    model = configs.get(args.arch)
    print(f"sim backend: system={args.system} model={model.name} "
          f"({args.instances} instances)")
    n = args.requests if not args.smoke else min(args.requests, 16)
    wl = WorkloadConfig(kind=args.workload, rps=args.rps,
                        n_requests=n, max_new_tokens=args.max_new,
                        prefix_share=args.prefix_share)
    scfg = SimConfig.preset(model, args.system, n_instances=args.instances)
    if args.speculation != "off":
        scfg = dataclasses.replace(
            scfg, speculation=args.speculation,
            draft_model=(model.smoke() if args.speculation == "draft"
                         else None))
    sim = ClusterSim(scfg)
    return sim, wl, 1.0    # report in seconds


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("live", "sim"), default="live")
    ap.add_argument("--arch", default="llama-13b")
    ap.add_argument("--smoke", action="store_true",
                    help="CPU-sized model (live) / shrunken workload (sim)")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rps", type=float, default=2.0,
                    help="live: arrivals per decode-iteration time; "
                         "sim: arrivals/s")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prefix-share", type=float, default=0.6)
    ap.add_argument("--prefill", type=int, default=2)
    ap.add_argument("--decode", type=int, default=2)
    ap.add_argument("--system", default="banaserve",
                    choices=("banaserve", "distserve", "vllm"))
    ap.add_argument("--workload", default="alpaca",
                    choices=("alpaca", "longbench", "synthetic"))
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--closed-loop", type=int, default=0, metavar="K",
                    help="K fixed-concurrency clients instead of "
                         "open-loop Poisson arrivals")
    ap.add_argument("--admission-limit", type=int, default=None,
                    help="max requests in flight; overflow is REJECTED")
    ap.add_argument("--speculation", choices=("off", "ngram", "draft"),
                    default="off",
                    help="multi-token speculative decoding on decode units "
                         "(live: exact verify on the paged KV; sim: "
                         "analytical twin); 'draft' uses the arch's smoke "
                         "shrink as the draft model")
    ap.add_argument("--autoscale", action="store_true",
                    help="SLO-driven elastic prefill/decode tiers: scale-up "
                         "bills warm-up on the virtual clock, scale-down "
                         "drains in-flight requests before retiring")
    ap.add_argument("--profiles", default=None, metavar="P1,P2",
                    help="hardware menu for autoscaled instances, e.g. "
                         "tpu_v5e,tpu_v5p (see core.analytical.PROFILES); "
                         "decode orders land on the highest-HBM-bw part, "
                         "prefill on the highest-FLOPs part")
    args = ap.parse_args()

    backend, wl, tscale = (_build_live if args.backend == "live"
                           else _build_sim)(args)
    autoscaler = None
    if args.autoscale:
        from ..core import analytical as A
        from ..serving.autoscale import AutoscaleConfig
        menu = (tuple(A.PROFILES[p] for p in args.profiles.split(","))
                if args.profiles else None)
        autoscaler = AutoscaleConfig(profiles=menu)
    server = Server(backend, admission_limit=args.admission_limit,
                    autoscaler=autoscaler)
    print(f"fleet: {server.fleet}")

    def pump() -> None:
        """Print each request's first-token and terminal stream events."""
        for h in server.handles.values():
            for ev in h.events():
                r = h.request
                if ev.kind == "token" and ev.index == 0:
                    print(f"req {r.rid:3d} first token @ "
                          f"{ev.t * tscale:10.2f} "
                          f"(ttft {r.ttft * tscale:8.2f})")
                elif ev.kind in ("completed", "aborted", "rejected"):
                    print(f"req {r.rid:3d} {ev.kind:9s} prompt="
                          f"{r.prompt_len:4d} out={len(r.generated):3d} "
                          f"cached={r.cached_tokens:3d}")

    if args.closed_loop:
        clients = ClosedLoopClients(wl, n_clients=args.closed_loop)
        s = server.run_closed_loop(clients)
        pump()
    else:
        for r in generate(wl):
            server.submit(r, at=r.arrival)
        while server.in_flight() and server.backend.clock:
            server.step()
            pump()
        server.drain()
        pump()
        s = server.summary()

    unit = "us" if tscale == 1e6 else "s"
    print(f"\n== {s['n_requests']} completed / {s['n_rejected']} rejected "
          f"/ {s['n_aborted']} aborted of {s['n_submitted']} submitted")
    print(f"throughput={s['throughput_tok_s']:.1f} tok/s  "
          f"mean_ttft={s['mean_ttft_s'] * tscale:.2f}{unit}  "
          f"p99_ttft={s['p99_ttft_s'] * tscale:.2f}{unit}  "
          f"mean_tpot={s['mean_tpot_s'] * tscale:.3f}{unit}")
    if s.get("speculation", "off") != "off":
        acc = s.get("acceptance_rate")
        tpi = s.get("tokens_per_decode_iter")
        print(f"speculation={s['speculation']}  "
              f"tokens/iter={'n/a' if tpi is None else f'{tpi:.2f}'}  "
              f"acceptance={'n/a' if acc is None else f'{acc:.2f}'}  "
              f"spec_iters={s.get('spec_iters', 0)} "
              f"plain_iters={s.get('spec_plain_iters', 0)}")
    if args.autoscale:
        print(f"autoscale: {s.get('autoscale_decisions', 0)} decisions, "
              f"{s.get('n_retired', 0)} instances retired")
    print(f"fleet now: {server.fleet}")


if __name__ == "__main__":
    main()
