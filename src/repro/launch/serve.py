"""Serving driver: a live disaggregated deployment on the host — prefill
engine + Global KV Cache Store + decode engine, batched Poisson requests.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --smoke \\
        --requests 24 --rps 8
"""
from __future__ import annotations

import argparse
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..core.kvstore import GlobalKVStore
from ..models import transformer as T
from ..serving.engine import DecodeEngine, EngineConfig, PrefillEngine
from ..serving.request import Metrics
from ..serving.workload import WorkloadConfig, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-13b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rps", type=float, default=8.0)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prefix-share", type=float, default=0.6)
    args = ap.parse_args()

    cfg = configs.get(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"serving arch={cfg.name} params={cfg.param_count():,}")
    params = T.init(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_len=args.max_len, max_batch=args.max_batch,
                        block_size=16)
    store = GlobalKVStore(block_size=16)
    pe = PrefillEngine(cfg, params, ecfg, store)
    de = DecodeEngine(cfg, params, ecfg)

    wl = WorkloadConfig(kind="synthetic", rps=args.rps,
                        n_requests=args.requests,
                        vocab_size=cfg.vocab_size,
                        max_new_tokens=args.max_new,
                        prefix_share=args.prefix_share,
                        n_prefix_groups=2,
                        prompt_len_lo=16,
                        prompt_len_hi=min(64, args.max_len // 2))
    reqs = generate(wl)
    metrics = Metrics()
    t0 = time.time()
    frames = (jnp.zeros((1, cfg.n_frames, cfg.d_model))
              if cfg.cross_attention else None)

    pending = deque(reqs)
    done = 0
    while done < len(reqs):
        # admit while slots are free (continuous batching)
        while pending and de.free_slot() is not None:
            r = pending.popleft()
            r.t_prefill_start = time.time() - t0
            st, logits = pe.run(r, frames=frames)
            first = int(jnp.argmax(logits))
            de.insert(r, st, first)
            r.t_first_token = time.time() - t0
        for r, _slot in de.step():
            r.t_done = time.time() - t0
            metrics.record(r)
            done += 1
            print(f"req {r.rid:3d} prompt={r.prompt_len:4d} "
                  f"cached={r.cached_tokens:4d} out={len(r.generated):4d} "
                  f"ttft={r.ttft:.3f}s tpot={(r.tpot or 0) * 1e3:.1f}ms")
    s = metrics.summary()
    print(f"\n== {s['n_requests']} requests  "
          f"throughput={s['throughput_tok_s']:.1f} tok/s  "
          f"mean_ttft={s['mean_ttft_s']:.3f}s  "
          f"mean_tpot={s['mean_tpot_s'] * 1e3:.1f}ms")
    print(f"store: {len(store)} blocks, hit_rate={store.stats.hit_rate:.2f}, "
          f"fetched={store.stats.bytes_fetched / 1e6:.1f} MB")


if __name__ == "__main__":
    main()
