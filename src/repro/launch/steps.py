"""The pjit-able step functions the dry-run (and real drivers) lower.

``build(cfg, shape, mesh)`` returns (fn, example_args, in_shardings,
out_shardings) ready for ``jax.jit(fn, ...).lower(*args)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import transformer as T
from ..models.config import ModelConfig
from ..training import optimizer as O
from ..training.train_step import make_train_step
from . import specs as S
from .sharding import ShardingPolicy, tree_shardings

# MoE capacity factor for production lowering (token-dropping, bounded
# buffers); tests use None (no-drop exact mode).
MOE_CF = 1.25
# Gradient-accumulation microbatches for the train_4k lowering: bounds
# activation memory at global_batch=256, seq=4096.
TRAIN_MICROBATCHES = 8


def build(cfg: ModelConfig, shape: S.ShapeSpec, mesh,
          dtype=jnp.bfloat16,
          *,
          kv_quant: bool = False,
          weight_quant: bool = False,
          moe_impl: str = "sorted",
          moe_cf=MOE_CF,
          shard_logits: bool = False,
          ) -> Tuple[Any, tuple, Any, Any, tuple]:
    """Knobs beyond the baseline (used by the §Perf hillclimb):
    kv_quant      int8 KV cache with per-(token, head) scales
    moe_impl      "sorted" (active-FLOPs dispatch) | "dense" (all experts)
    moe_cf        MoE capacity factor (None = no-drop)
    shard_logits  leave serve-step logits vocab-sharded (skip the gather)
    """
    cfg = S.arch_for_shape(cfg, shape)
    if kv_quant:
        cfg = cfg.with_kv_quant()
    if weight_quant and shape.kind == "train":
        raise ValueError("int8 weights are a serving-only optimization")
    if shape.kind == "train" and not cfg.replicate_small():
        # training always shards weights/grads/optimizer 2D (ZeRO-3 style):
        # the f32 Adam state is 4x the bf16 weights, model-axis-only
        # sharding would blow HBM on every >=8B model
        cfg = dataclasses.replace(cfg, fsdp_weights=True)
    policy = ShardingPolicy(mesh, cfg,
                            seq_shard=(shape.name == "long_500k"))
    ins = S.input_specs(cfg, shape, dtype)
    params = S.param_shapes(cfg, dtype)
    param_hook = None
    if weight_quant:
        import dataclasses as _dc

        from ..models.quant import is_quantized, quantize_weights
        params = jax.eval_shape(quantize_weights, params)
        # per-layer weight gather must happen on the int8 payload (half the
        # FSDP all-gather bytes): constrain each q to its no-FSDP spec
        # inside the scan body, before dequantization
        nofsdp = ShardingPolicy(
            mesh, _dc.replace(cfg, fsdp_weights=False),
            seq_shard=policy.seq_shard)

        def param_hook(layer_p):
            def one(path, leaf):
                names = "/".join(str(getattr(k, "key",
                                             getattr(k, "idx", k)))
                                 for k in path)
                if names.endswith("/q"):
                    spec = nofsdp.param_spec(names, leaf.shape)
                    return jax.lax.with_sharding_constraint(leaf, spec)
                return leaf
            return jax.tree_util.tree_map_with_path(one, layer_p)
    p_shard = tree_shardings(policy, params, "param")
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = O.AdamWConfig()
        opt_state = jax.eval_shape(O.init_state, params)
        o_shard = {"mu": p_shard, "nu": p_shard, "step": rep}
        d_ok = (cfg.d_model % mesh.shape["model"] == 0
                and not cfg.replicate_small())
        act_spec = P(policy.dp, None, "model") if d_ok else \
            P(policy.dp, None, None)
        step = make_train_step(cfg, opt_cfg, moe_impl=moe_impl,
                               moe_cf=moe_cf, remat=True,
                               num_microbatches=TRAIN_MICROBATCHES,
                               act_spec=act_spec)
        tok_sh = NamedSharding(mesh, policy.tokens_spec(shape.global_batch))
        b_shard: Dict[str, Any] = {"tokens": tok_sh}
        if cfg.cross_attention:
            b_shard["frames"] = NamedSharding(
                mesh, policy.frames_spec(shape.global_batch))
        in_sh = (p_shard, o_shard, b_shard)
        out_sh = (p_shard, o_shard, rep)
        args = (params, opt_state, ins["batch"])
        return step, args, in_sh, out_sh, (0, 1)     # donate params+opt

    cache = ins["cache"]
    c_shard = tree_shardings(policy, cache, "cache")
    tok_sh = NamedSharding(mesh, policy.tokens_spec(shape.global_batch))
    logits_sh = rep

    if shape.kind == "prefill":
        def fn(params, tokens, cache, frames=None):
            logits, new_cache, _ = T.apply(
                cfg, params, tokens, cache=cache, frames=frames,
                mode="prefill", moe_impl=moe_impl, moe_cf=moe_cf,
                moe_mesh=mesh, fresh_prefill=True, logits_slice="last",
                param_hook=param_hook)
            return logits, new_cache
    else:
        def fn(params, tokens, cache, frames=None):
            logits, new_cache, _ = T.apply(
                cfg, params, tokens, cache=cache, frames=frames,
                mode="decode", moe_impl=moe_impl, moe_cf=moe_cf,
                moe_mesh=mesh, logits_slice="last", param_hook=param_hook)
            return logits, new_cache

    if shard_logits:
        logits_sh = NamedSharding(
            mesh, P(None, "model" if cfg.vocab_size
                    % mesh.shape["model"] == 0 else None))
    args = [params, ins["tokens"], cache]
    in_sh = [p_shard, tok_sh, c_shard]
    if cfg.cross_attention:
        args.append(ins["frames"])
        in_sh.append(NamedSharding(mesh,
                                   policy.frames_spec(shape.global_batch)))
    out_sh = (logits_sh, c_shard)
    return fn, tuple(args), tuple(in_sh), out_sh, (2,)   # donate cache
