"""Sharding rules: pytree path + shape -> PartitionSpec.

Policy (DESIGN.md §5):
* batch            -> ("pod","data")                      [all shapes]
* attention heads / FFN hidden / vocab -> "model"
* GQA KV heads     -> "model" only when divisible, else replicated
  (standard GQA TP practice: KV replicates when TP > n_kv_heads)
* weights of >=100B models additionally shard their non-head dim over
  "data" (ZeRO-3 / FSDP style) so per-chip bytes fit 16 GB v5e HBM
* KV cache         -> batch over ("pod","data"), sequence over "model"
  (decode attention over a model-sharded sequence IS the paper's split-KV
  partial-softmax combine, executed by XLA's sharded softmax collectives)
* long_500k (batch=1) -> KV sequence over ("pod","data","model"):
  full context parallelism
* tiny models (<1.5 GB bf16) replicate weights entirely: collective-free
  decode

Every rule falls back to replication when a dimension is not divisible by
the axis size — correctness first, the roofline report shows the cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.config import BlockKind, ModelConfig
from .mesh import data_axes

REPLICATE_BYTES = int(1.5e9)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _maybe(mesh: Mesh, axis, dim: int):
    """Use ``axis`` only when ``dim`` divides evenly."""
    if axis is None or dim % _axis_size(mesh, axis) != 0:
        return None
    # normalize singleton axis tuples to bare names: ("data",) and "data"
    # mean the same sharding but no longer compare equal as spec entries
    if isinstance(axis, tuple):
        if not axis:
            return None
        if len(axis) == 1:
            return axis[0]
    return axis


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig
    seq_shard: bool = False        # long_500k: context parallelism

    @property
    def dp(self):
        return data_axes(self.mesh)

    @property
    def fsdp(self):
        """Extra weight-sharding axis for huge models."""
        return self.dp if self.cfg.fsdp_weights else None

    @property
    def replicate_all(self) -> bool:
        return self.cfg.replicate_small()

    # -- parameters -----------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        m = self.mesh
        if self.replicate_all:
            return P()
        parts = path.split("/")
        name = parts[-1]
        if name == "s":                  # int8 scale scalar: replicate
            return P()
        if name == "q":                  # int8 payload: parent weight's rule
            name = parts[-2]
        stacked = path.startswith("groups")     # leading repeat dim
        pre = (None,) if stacked else ()

        def spec(*axes):
            return P(*(pre + axes))

        cfg = self.cfg
        base = shape[1:] if stacked else shape
        if name == "embed" or name == "unembed":
            # (V, d) / (d, V)
            big, small = (0, 1) if name == "embed" else (1, 0)
            out = [None, None]
            out[big] = _maybe(m, "model", shape[big])
            out[small] = _maybe(m, self.fsdp, shape[small])
            return P(*out)
        if name in ("wq",):                      # (d, H, hd)
            return spec(_maybe(m, self.fsdp, base[0]),
                        _maybe(m, "model", base[1]), None)
        if name in ("wk", "wv"):                 # (d, KV, hd)
            return spec(_maybe(m, self.fsdp, base[0]),
                        _maybe(m, "model", base[1]), None)
        if name == "wo":                         # (H, hd, d)
            return spec(_maybe(m, "model", base[0]), None,
                        _maybe(m, self.fsdp, base[2]))
        if name in ("w_gate", "w_up"):
            if len(base) == 3:                   # MoE (E, d, f)
                return spec(None, _maybe(m, self.fsdp, base[1]),
                            _maybe(m, "model", base[2]))
            return spec(_maybe(m, self.fsdp, base[0]),
                        _maybe(m, "model", base[1]))
        if name == "w_down":
            if len(base) == 3:                   # MoE (E, f, d)
                return spec(None, _maybe(m, "model", base[1]),
                            _maybe(m, self.fsdp, base[2]))
            return spec(_maybe(m, "model", base[0]),
                        _maybe(m, self.fsdp, base[1]))
        if name == "router":                     # (d, E)
            return spec(_maybe(m, self.fsdp, base[0]), None)
        if name in ("w_x", "w_y", "w_a", "w_i", "w_out", "w_o"):
            return spec(_maybe(m, self.fsdp, base[0]),
                        _maybe(m, "model", base[1]))
        if name in ("w_gates", "r_gates", "w_if"):
            return spec(_maybe(m, self.fsdp, base[0]),
                        _maybe(m, "model", base[1]))
        if name == "conv_w":                     # (W, d)
            return spec(None, _maybe(m, "model", base[1]))
        if name == "a_param":                    # (d,)
            return spec(_maybe(m, "model", base[0]))
        # norms, biases, everything else: replicate
        return P(*((None,) * len(shape)))

    # -- serving state ----------------------------------------------------
    def cache_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        m = self.mesh
        name = path.split("/")[-1]
        stacked = "groups" in path
        pre = (None,) if stacked else ()
        base = shape[1:] if stacked else shape

        def spec(*axes):
            return P(*(pre + axes))

        batch_ax = None if self.seq_shard else \
            _maybe(m, self.dp, base[0] if base else 1)
        seq_axes = ("pod", "data", "model") if self.seq_shard else ("model",)
        seq_axes = tuple(a for a in seq_axes if a in m.axis_names)
        if name == "lengths":
            return P(_maybe(m, self.dp, shape[0])
                     if not self.seq_shard else None)
        if name in ("k", "v"):                  # (B, L, KV, D)
            return spec(batch_ax, _maybe(m, seq_axes, base[1]), None, None)
        if name == "pos":                        # (B, L)
            return spec(batch_ax, _maybe(m, seq_axes, base[1]))
        if name in ("k_scale", "v_scale"):       # (B, L, KV)
            return spec(batch_ax, _maybe(m, seq_axes, base[1]), None)
        if name == "h" and len(base) == 2:       # rglru (B, d)
            return spec(batch_ax, _maybe(m, "model", base[1]))
        if name == "conv":                       # (B, W-1, d)
            return spec(batch_ax, None, _maybe(m, "model", base[2]))
        if name in ("C", "n", "m", "c"):         # xlstm states
            return spec(batch_ax, *((None,) * (len(base) - 1)))
        if name == "h":                          # slstm h (B, d)
            return spec(batch_ax, *((None,) * (len(base) - 1)))
        return spec(*((None,) * len(base)))

    # -- batches ----------------------------------------------------------
    def tokens_spec(self, batch: int) -> P:
        return P(_maybe(self.mesh, self.dp, batch), None)

    def frames_spec(self, batch: int) -> P:
        return P(_maybe(self.mesh, self.dp, batch), None, None)


def tree_shardings(policy: ShardingPolicy, tree, kind: str):
    """Map a params ('param') or cache ('cache') pytree to NamedShardings."""
    fn = policy.param_spec if kind == "param" else policy.cache_spec

    def one(path, leaf):
        spec = fn(_path_str(path), leaf.shape)
        return NamedSharding(policy.mesh, spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def opt_state_shardings(policy: ShardingPolicy, param_shardings,
                        opt_state_shape):
    """mu/nu mirror the param shardings; counters replicate."""
    rep = NamedSharding(policy.mesh, P())
    return {"mu": param_shardings, "nu": param_shardings, "step": rep}
