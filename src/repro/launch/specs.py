"""Assigned input shapes and ShapeDtypeStruct stand-ins for the dry-run.

Shapes (assignment):
    train_4k       seq_len=4,096    global_batch=256   (training)
    prefill_32k    seq_len=32,768   global_batch=32    (inference-prefill)
    decode_32k     seq_len=32,768   global_batch=128   (inference-decode)
    long_500k      seq_len=524,288  global_batch=1     (long-context-decode)

Decode shapes lower ``serve_step`` — ONE new token with a KV cache of
seq_len — not ``train_step``.  ``long_500k`` requires sub-quadratic
attention: hybrid/ssm archs run natively; pure-attention archs run their
sliding-window variant (window 8192, DESIGN.md §4) — a beyond-paper
extension so the combination still exercises the serving stack.

No device allocation happens here: everything is jax.ShapeDtypeStruct.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models.config import ModelConfig

LONG_WINDOW = 8192


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def arch_for_shape(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """long_500k needs sub-quadratic attention: dense/moe/vlm/audio archs
    switch to their sliding-window variant; hybrid/ssm run natively."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return cfg.with_sliding_window(LONG_WINDOW)
    return cfg


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(cfg: ModelConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStructs of the full parameter pytree (no allocation)."""
    return jax.eval_shape(
        lambda: T.init(cfg, jax.random.PRNGKey(0), dtype=dtype))


def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len, dtype=dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the lowered step."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if shape.kind == "train":
        out["batch"] = {"tokens": _sds((b, s + 1), jnp.int32)}
        if cfg.cross_attention:
            out["batch"]["frames"] = _sds((b, cfg.n_frames, cfg.d_model),
                                          dtype)
        return out
    if shape.kind == "prefill":
        out["tokens"] = _sds((b, s), jnp.int32)
        out["cache"] = cache_shapes(cfg, b, s, dtype)
    else:  # decode: one new token against a seq_len-deep cache
        out["tokens"] = _sds((b, 1), jnp.int32)
        out["cache"] = cache_shapes(cfg, b, s, dtype)
    if cfg.cross_attention:
        out["frames"] = _sds((b, cfg.n_frames, cfg.d_model), dtype)
    return out
