"""Pipeline-parallel decode (§Perf pair-1, iteration 4).

FSDP weight-gathered decode has a hard collective floor: every step moves
``weights/model_axis`` bytes per chip (llama3-405b: ~600 ms even at int8).
The structural fix is to let each data-axis slice OWN a contiguous span of
layers outright (pipeline stages × tensor parallelism within a stage):

* per-chip weight residency is identical to 2-D FSDP (W / (stages × TP)),
* but nothing is gathered — the only inter-stage traffic is the (µB, d)
  activation handed between stages via ``collective_permute``.

Decode batch B is split into ``n_stages`` microbatches fed GPipe-style;
after the fill latency every stage works every tick.  Implemented as a
``shard_map`` over the "data" axis with the "model" axis left to GSPMD
(per-stage tensor parallelism stays automatic).

Restrictions: dense decoder-only archs (uniform block pattern), decode step
only.  Layer count is padded to a multiple of the stage count with exact
identity blocks (zero output projections — residual passthrough).
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models import layers as L
from ..models import quant as Q
from ..models import transformer as T
from ..models.config import BlockKind, ModelConfig


def _shard_map(fn, mesh, in_specs, out_specs, manual_axes):
    """jax API drift shim: shard_map moved out of jax.experimental, and its
    kwargs changed (check_rep -> check_vma, auto -> axis_names) — detect
    each by signature since the changes landed in different releases.

    ``manual_axes`` are the axes ``fn`` references; with axis_names support
    the rest stay GSPMD-sharded.  The old partial-auto mode trips XLA's
    PartitionId limitation, so without axis_names we run fully manual: axes
    absent from the specs are replicated inside the body — identical
    results, duplicated compute."""
    try:
        sm = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw = ({"check_vma": False} if "check_vma" in params
          else {"check_rep": False})
    if "axis_names" in params:
        kw["axis_names"] = set(manual_axes)
    return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def pad_layers(cfg: ModelConfig, n_stages: int) -> Tuple[int, int]:
    """(layers_per_stage, n_pad) so stages divide the (padded) stack."""
    total = -(-cfg.n_layers // n_stages) * n_stages
    return total // n_stages, total - cfg.n_layers


def pad_stacked_params(cfg: ModelConfig, params, n_pad: int):
    """Append ``n_pad`` identity layers (zero wo / w_down => residual
    passthrough) to the stacked group params."""
    if n_pad == 0:
        return params
    def pad_leaf(path, a):
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        padder = jnp.zeros((n_pad,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, padder], axis=0)
    g0 = jax.tree_util.tree_map_with_path(pad_leaf, params["groups"][0])
    out = dict(params)
    out["groups"] = (g0,)
    return out


def pad_stacked_cache(cache, n_pad: int):
    if n_pad == 0:
        return cache
    def pad_leaf(a):
        return jnp.concatenate(
            [a, jnp.zeros((n_pad,) + a.shape[1:], a.dtype)], axis=0)
    out = dict(cache)
    out["groups"] = (jax.tree.map(pad_leaf, cache["groups"][0]),)
    return out


def build_pipeline_decode(cfg: ModelConfig, mesh, batch: int):
    """Returns decode_fn(params, tokens, cache) -> (logits, new_cache),
    pipelined over the mesh's "data" axis."""
    assert len(cfg.block_pattern) == 1 and \
        cfg.block_pattern[0] in (BlockKind.ATTENTION,
                                 BlockKind.LOCAL_ATTENTION), \
        "pipeline decode: dense uniform stacks only"
    n_stages = mesh.shape["data"]
    assert batch % n_stages == 0, (batch, n_stages)
    mb = batch // n_stages
    per_stage, n_pad = pad_layers(cfg, n_stages)
    window = cfg.sliding_window

    def stage_fn(params_st, tokens, cache_g, lengths):
        """One device = one stage.  params_st: (per_stage, ...) layer stack;
        cache_g: stage's cache slice (per_stage, B, L, KV, D...)."""
        stage = jax.lax.axis_index("data")
        compute_dtype = params_st["out_norm"].dtype
        embed = Q.dequant(params_st["embed"], compute_dtype)

        n_ticks = 2 * n_stages - 1
        logits_acc = jnp.zeros((batch, cfg.vocab_size), jnp.float32)

        def tick(carry, t):
            cache_g, x_recv, logits_acc = carry
            m = t - stage                      # µbatch index at this stage
            valid = (m >= 0) & (m < n_stages)
            mc = jnp.clip(m, 0, n_stages - 1)
            # µbatch rows [mc*mb, (mc+1)*mb)
            toks_m = jax.lax.dynamic_slice_in_dim(tokens, mc * mb, mb, 0)
            len_m = jax.lax.dynamic_slice_in_dim(lengths, mc * mb, mb, 0)
            x0 = embed[toks_m].astype(embed.dtype)
            x = jnp.where(stage == 0, x0, x_recv)
            positions = len_m[:, None]

            cache_m = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, mc * mb, mb, 1),
                cache_g)

            def layer(xc, xs):
                x, st = xc, xs[1]
                lp = xs[0]
                y, ns, _ = T._apply_block(
                    cfg, cfg.block_pattern[0], lp, x,
                    positions=positions, state=st, mode="decode",
                    frames=None, moe_impl="sorted", moe_cf=None)
                return y, ns
            x, new_cache_m = jax.lax.scan(layer, x, (params_st["groups"],
                                                     cache_m))
            # masked write-back of the µbatch cache rows
            def put(a, new):
                cur = jax.lax.dynamic_slice_in_dim(a, mc * mb, mb, 1)
                sel = jnp.where(valid, new, cur)
                return jax.lax.dynamic_update_slice_in_dim(a, sel, mc * mb, 1)
            cache_g = jax.tree.map(put, cache_g, new_cache_m)

            # final stage: normalized logits for this µbatch
            h = L.rms_norm(x, params_st["out_norm"], cfg.rms_eps)
            if cfg.tie_embeddings:
                lg = jnp.einsum("bsd,vd->bsv", h, embed)[:, -1]
            else:
                lg = jnp.einsum("bsd,dv->bsv", h,
                                Q.dequant(params_st["unembed"],
                                          compute_dtype))[:, -1]
            is_last = stage == n_stages - 1
            upd = jnp.where(valid & is_last, lg.astype(jnp.float32), 0.0)
            cur = jax.lax.dynamic_slice_in_dim(logits_acc, mc * mb, mb, 0)
            logits_acc = jax.lax.dynamic_update_slice_in_dim(
                logits_acc, cur + upd, mc * mb, 0)

            # hand activations to the next stage
            x_send = jax.lax.ppermute(
                x, "data", [(i, i + 1) for i in range(n_stages - 1)])
            return (cache_g, x_send, logits_acc), ()

        (cache_g, _, logits_acc), _ = jax.lax.scan(
            tick, (cache_g, jnp.zeros((mb, 1, cfg.d_model),
                                      embed.dtype), logits_acc),
            jnp.arange(n_ticks))
        # only the last stage holds real logits: sum-reduce across stages
        logits = jax.lax.psum(logits_acc, "data")
        return logits, cache_g, lengths + 1

    def decode_fn(params, tokens, cache):
        p_specs = {
            "embed": P(),
            "out_norm": P(),
            "groups": jax.tree.map(lambda _: P("data"), params["groups"][0]),
        }
        if "unembed" in params:
            p_specs["unembed"] = P()
        p_in = {k: params[k] for k in p_specs if k != "groups"}
        p_in["groups"] = params["groups"][0]     # the stacked layer dict
        c_specs = jax.tree.map(lambda _: P("data"), cache["groups"][0])
        logits, new_g, new_len = _shard_map(
            stage_fn, mesh,
            in_specs=(p_specs, P(), c_specs, P()),
            out_specs=(P(), c_specs, P()),
            manual_axes={"data"})(p_in, tokens, cache["groups"][0],
                                  cache["lengths"])
        new_cache = {"lengths": new_len, "groups": (new_g,),
                     "rem": cache.get("rem", ())}
        return logits, new_cache

    return decode_fn, per_stage, n_pad
