"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax init;
tests and benches must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi pod:  (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU tests of the sharding-annotated code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """The batch-sharding axes: ("pod", "data") on multi-pod meshes."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
