import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) combination against the production meshes, with 512 placeholder host
devices (the two lines above MUST precede any jax import — jax locks the
device count on first init; do NOT set this flag globally).

Per combination this records:
  * compiled.memory_analysis()  — per-chip bytes (does it fit 16 GB v5e?)
  * compiled.cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes parsed from the compiled HLO (hlo_analysis)

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and a
summary table on stdout.  EXPERIMENTS.md §Dry-run / §Roofline are built
from these files.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all 40 × 2
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape decode_32k
    PYTHONPATH=src python -m repro.launch.dryrun --mesh single      # 16x16 only
"""
import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp

from .. import configs
from ..models import layers as L
from ..models.config import ModelConfig
from . import hlo_analysis as H
from . import specs as S
from . import steps
from .mesh import make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def analytical_bytes_per_chip(cfg: ModelConfig, shape: S.ShapeSpec,
                              n_chips: int, mesh) -> float:
    """Per-chip HBM traffic for one step, from the workload model.

    The HLO-walker byte count is unusable on the CPU backend (bf16 matmul
    operands are converted to f32 and the converts get hoisted over whole
    loop-carried caches — artifacts a TPU compile does not have), so the
    memory roofline term uses the §4.3 analytical traffic model:
      decode:  resident weight shard + KV shard read once per step
      prefill: weight shard (re-read per q-block tile) + KV write + 2x
               activations per layer
      train:   3x prefill compute traffic + optimizer state update
    """
    model_axis = mesh.shape["model"]
    w_bytes = cfg.active_param_count() * 2
    w_chip = w_bytes / (n_chips if cfg.fsdp_weights else model_axis)
    if cfg.replicate_small():
        w_chip = w_bytes
    kv_len = cfg.kv_cache_len(shape.seq_len)
    kv_total = cfg.kv_bytes_per_token() * kv_len * shape.global_batch
    kv_chip = kv_total / n_chips
    if shape.kind == "decode":
        return w_chip + kv_chip
    toks_chip = shape.global_batch * shape.seq_len / max(
        n_chips / model_axis, 1)
    act_chip = toks_chip * cfg.d_model * 2 * 4 * cfg.n_layers / model_axis
    if shape.kind == "prefill":
        return w_chip + 2 * kv_chip + act_chip
    # train: fwd + 2x bwd activation traffic + Adam state (14 B/param)
    opt_chip = cfg.param_count() * 14 / (n_chips if cfg.fsdp_weights
                                         else model_axis)
    return 3 * (w_chip + act_chip) + opt_chip


def model_flops(cfg: ModelConfig, shape: S.ShapeSpec) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N·D for inference (N = active)."""
    n = cfg.active_param_count()
    d_tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                     else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d_tokens


def _loop_trips(cfg: ModelConfig, shape: S.ShapeSpec) -> tuple:
    pat_len = len(cfg.block_pattern)
    n_rep = cfg.n_layers // pat_len
    if shape.kind in ("train", "prefill") and \
            shape.seq_len > L.ATTN_BLOCK_THRESHOLD:
        nq = math.ceil(shape.seq_len / L.ATTN_BLOCK_Q)
        return (n_rep, nq)
    if shape.kind != "decode" and cfg.uses_recurrent_state:
        return (n_rep, shape.seq_len)
    return (n_rep,)


def run_one(arch: str, shape_name: str, mesh_kind: str,
            out_dir: str = OUT_DIR, verbose: bool = True) -> dict:
    cfg0 = configs.get(arch)
    shape = S.SHAPES[shape_name]
    cfg = S.arch_for_shape(cfg0, shape)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "n_chips": int(n_chips), "variant": cfg.name, "ok": False}
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate = steps.build(cfg0, shape, mesh)
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                              donate_argnums=donate).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        # jax API drift: cost_analysis() returns a per-device list of dicts
        # on some versions and a bare dict on others
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        trips = _loop_trips(cfg, shape)
        coll = H.parse_collectives(hlo, trips)
        # cost_analysis() counts while bodies once (verified); use the
        # loop-weighted HLO-walker dot FLOPs (exact) and the analytical
        # traffic model for bytes (walker bytes carry CPU-backend convert
        # artifacts — see analytical_bytes_per_chip docstring)
        flops = coll.dot_flops
        byts = analytical_bytes_per_chip(cfg, shape, int(n_chips), mesh)
        rec.update({
            "ok": True,
            "compile_s": time.time() - t0,
            "flops": flops,
            "bytes_accessed": byts,
            "hlo_walker_bytes": coll.hlo_bytes,
            "cost_analysis_flops_raw": float(cost.get("flops", 0.0)),
            "cost_analysis_bytes_raw": float(cost.get("bytes accessed", 0.0)),
            "collective_bytes": coll.total_bytes,
            "collective_detail": coll.bytes_by_kind,
            "collective_counts": coll.count_by_kind,
            "loop_trips": list(trips),
            "model_flops": model_flops(cfg, shape),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
                "temp_arena_bytes": getattr(mem, "temp_size_in_bytes", 0),
                "peak_temp_bytes": getattr(mem, "peak_memory_in_bytes", 0),
            },
        })
        # Memory accounting (calibrated — see EXPERIMENTS.md §Dry-run):
        #  * resident = args + outputs − alias: params/KV/opt state under the
        #    chosen shardings.  Exact and backend-independent.
        #  * temp arena: CPU-backend transient bound.  INFLATED vs TPU: the
        #    CPU backend converts bf16 matmul operands to f32 and hoists
        #    those converts over whole loop-carried caches (observed in the
        #    HLO dumps); TPU's native-bf16 MXU path has no such buffers.
        # fits_16g is judged on resident + the arena capped at the
        # pre-hoisting estimate is NOT attempted — both numbers reported.
        mrec = rec["memory"]
        resident = (mrec["argument_bytes"] + mrec["output_bytes"]
                    - mrec["alias_bytes"])
        per_chip = resident + mrec["temp_arena_bytes"]
        rec["resident_bytes_per_chip"] = resident
        rec["bytes_per_chip"] = per_chip
        rec["fits_16g"] = bool(resident < 16e9)
        rec["fits_16g_with_cpu_arena"] = bool(per_chip < 16e9)
        roof = H.Roofline(arch, shape_name, mesh_kind, int(n_chips),
                          flops, byts, coll.total_bytes,
                          rec["model_flops"], per_chip)
        rec["roofline"] = roof.as_dict()
        if verbose:
            print(f"  OK   {arch:24}{shape_name:13}{mesh_kind:7}"
                  f" compile={rec['compile_s']:6.1f}s"
                  f" perchip={per_chip/2**30:7.2f}GiB"
                  f" fits={rec['fits_16g']}"
                  f" bottleneck={roof.bottleneck}")
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["compile_s"] = time.time() - t0
        if verbose:
            print(f"  FAIL {arch:24}{shape_name:13}{mesh_kind:7} {rec['error'][:120]}")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="one architecture (default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help="one shape (default: all four)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else configs.names(assigned_only=True)
    shapes = [args.shape] if args.shape else list(S.SHAPES)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]

    n_fail = 0
    for mesh_kind in meshes:
        print(f"=== mesh {mesh_kind} "
              f"({'2x16x16' if mesh_kind == 'multi' else '16x16'}) ===")
        for arch in archs:
            for shape in shapes:
                rec = run_one(arch, shape, mesh_kind, args.out)
                n_fail += 0 if rec["ok"] else 1
    print(f"done; failures: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
